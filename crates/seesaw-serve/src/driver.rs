//! Run drivers: the unit of work the serve scheduler multiplexes.
//!
//! A [`RunDriver`] is one tenant's training run, advanced one
//! scheduler-visible step at a time over a *borrowed* [`WorkerPool`] —
//! the lending contract that lets every run in the registry share one
//! set of parked threads
//! ([`seesaw_engine::coordinator::StepEngine::swap_pool`]). Two
//! productions:
//!
//! * [`TrainerDriver`] — the artifact-backed LM path: wraps a fully
//!   configured [`Trainer`] and drives exactly the
//!   `begin → run_step → finalize` decomposition `Trainer::run` itself
//!   loops over, so a multiplexed run cannot drift from a solo one.
//! * [`RecursionDriver`] — the artifact-free theory substrate: the
//!   exact golden-trajectory step loop (query → risk step → exact GNS →
//!   observe) over the NSGD risk recursion, emitting the same
//!   bit-pattern trace lines the committed fixtures pin. This is the
//!   driver the serve test suite replays the golden traces through.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};
use seesaw_core::linreg::recursion::{Problem, RiskIter};
use seesaw_core::metrics::RunLog;
use seesaw_core::schedule::Schedule;
use seesaw_engine::coordinator::{TrainState, Trainer, WorkerPool};
use seesaw_engine::experiments::adaptive_exps::exact_gns;

/// One tenant's run, as the fair-share scheduler sees it.
///
/// Contract: [`RunDriver::step`] advances the run by exactly one
/// trajectory step and returns the batch tokens it consumed (the
/// scheduler's fair-share charge) — or `Ok(0)` without side effects when
/// the run was already complete. The borrowed pool must be returned in
/// working order even if the step's own arithmetic panics; a panic or
/// error escaping `step` evicts the run, never the pool.
pub trait RunDriver {
    /// Advance one step over the lent pool; returns the tokens consumed.
    fn step(&mut self, pool: &mut WorkerPool) -> Result<u64>;

    /// True once the run's token budget is spent.
    fn is_done(&self) -> bool;

    /// End-of-run effects (final checkpoint, CSV dump). Called exactly
    /// once by the scheduler, after the step that completed the budget.
    fn finish(&mut self) -> Result<()>;

    /// The run's trajectory identity (what the `(lr, batch)` law hashes
    /// to) — recorded in the registry at submit.
    fn traj_identity(&self) -> String;

    /// The run's execution fingerprint (topology: world, collective,
    /// threads, overlap) — recorded in the registry at submit.
    fn exec_fingerprint(&self) -> String;

    /// Bind the tenant's checkpoint namespace (called by
    /// [`crate::Serve::submit`] before the first step when the service
    /// has a checkpoint root). Default: the driver does not checkpoint.
    fn bind_checkpoint_dir(&mut self, _dir: &Path) {}

    /// The run's trajectory so far as golden-comparable data lines
    /// (`step,lr_bits,batch,ce_bits,gnorm_bits,gns_bits,cuts`). Empty
    /// for drivers that log elsewhere.
    fn trace_lines(&self) -> Vec<String> {
        Vec::new()
    }

    /// One-line human summary of the run so far (what the CLI prints at
    /// end of run). Default: the driver has nothing to say.
    fn summary(&self) -> Option<String> {
        None
    }
}

/// Decode a panic payload into something loggable.
pub(crate) fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| (*s).to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

/// The artifact-backed LM driver: one [`Trainer`] stepped under the
/// scheduler instead of its own `run()` loop.
///
/// The session (`TrainState` + `RunLog`) begins lazily on the first
/// step, *after* [`RunDriver::bind_checkpoint_dir`] has pointed the
/// trainer at its tenant namespace — so a resume finds the tenant's own
/// `latest.ckpt`, never a sibling's.
pub struct TrainerDriver {
    trainer: Trainer,
    sess: Option<(TrainState, RunLog)>,
}

impl TrainerDriver {
    pub fn new(trainer: Trainer) -> Self {
        Self { trainer, sess: None }
    }

    /// The log accumulated so far (empty before the first step).
    pub fn log(&self) -> Option<&RunLog> {
        self.sess.as_ref().map(|(_, log)| log)
    }

    fn ensure_begun(&mut self) -> Result<()> {
        if self.sess.is_none() {
            let sess = self.trainer.begin().context("opening run")?;
            self.sess = Some(sess);
        }
        Ok(())
    }
}

impl RunDriver for TrainerDriver {
    fn step(&mut self, pool: &mut WorkerPool) -> Result<u64> {
        self.ensure_begun()?;
        let (state, log) = self.sess.as_mut().expect("session begun above");
        if self.trainer.is_done(state) {
            // resumed-at-budget (or re-picked after completion): the solo
            // `while !is_done` loop would run zero steps — mirror it.
            return Ok(0);
        }
        // Lend the shared pool for exactly one step. The swap-back runs
        // unconditionally — a panicking step must not walk off with the
        // service's parked threads — and the panic itself becomes this
        // run's eviction error, not the service's crash. (GradSource
        // panics on pool threads are already caught thread-side and
        // surface as plain `Err`s; this guard covers the sequential
        // path and the coordinator's own arithmetic.)
        let trainer = &mut self.trainer;
        trainer.engine.swap_pool(pool);
        let stepped = catch_unwind(AssertUnwindSafe(|| trainer.run_step(state, log)));
        trainer.engine.swap_pool(pool);
        match stepped {
            Ok(res) => res,
            Err(payload) => Err(anyhow!("run panicked mid-step: {}", panic_msg(&*payload))),
        }
    }

    fn is_done(&self) -> bool {
        match &self.sess {
            Some((state, _)) => self.trainer.is_done(state),
            None => false,
        }
    }

    fn finish(&mut self) -> Result<()> {
        self.ensure_begun()?;
        let (state, log) = self.sess.as_ref().expect("session begun above");
        self.trainer.finalize(state, log)
    }

    fn traj_identity(&self) -> String {
        self.trainer.cfg.trajectory_identity(self.trainer.total_tokens)
    }

    fn exec_fingerprint(&self) -> String {
        self.trainer.cfg.exec_fingerprint()
    }

    fn bind_checkpoint_dir(&mut self, dir: &Path) {
        assert!(
            self.sess.is_none(),
            "checkpoint namespace must be bound before the first step (resume \
             would otherwise have read the wrong directory)"
        );
        self.trainer.cfg.checkpoint_dir = Some(dir.to_path_buf());
    }

    fn summary(&self) -> Option<String> {
        let log = self.log()?;
        Some(format!(
            "done: {} steps, {} cuts, final train CE {:.4}, final val CE {}, serial time {:.1}s (modeled)",
            log.total_steps(),
            log.cut_count(),
            log.final_train_ce().unwrap_or(f64::NAN),
            log.final_val_ce().map(|v| format!("{v:.4}")).unwrap_or_else(|| "-".into()),
            log.total_serial_time()
        ))
    }
}

/// One replayed step of a recursion run — the golden-trace row.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRow {
    pub step: u64,
    pub lr: f64,
    pub batch: u64,
    /// Exact excess risk after the step — the CE stand-in.
    pub ce: f64,
    /// Exact `E‖g‖²` at the step's batch.
    pub gnorm: f64,
    /// Exact `B_noise` fed back to the schedule (`None`: signal ≤ 0).
    pub gns: Option<f64>,
    pub cuts: u32,
}

impl TraceRow {
    /// The golden fixture's data-line rendering: f64 fields as IEEE-754
    /// bit patterns, so comparisons are exact.
    pub fn render(&self) -> String {
        let gns = match self.gns {
            Some(v) => format!("{:016x}", v.to_bits()),
            None => "-".to_string(),
        };
        format!(
            "{},{:016x},{},{:016x},{:016x},{},{}",
            self.step,
            self.lr.to_bits(),
            self.batch,
            self.ce.to_bits(),
            self.gnorm.to_bits(),
            gns,
            self.cuts
        )
    }
}

/// The artifact-free driver: the exact golden step loop (query → cuts
/// edge → risk step → exact GNS → observe) over the NSGD risk recursion,
/// one loop iteration per scheduler step.
pub struct RecursionDriver {
    it: RiskIter,
    sched: Box<dyn Schedule>,
    total: u64,
    tokens: u64,
    step: u64,
    last_phase: usize,
    rows: Vec<TraceRow>,
    label: String,
    ckpt_dir: Option<PathBuf>,
}

impl RecursionDriver {
    /// A driver over `problem`'s exact risk recursion under `sched`.
    /// `label` names the trajectory in the registry and the checkpoint.
    pub fn new(problem: &Problem, sched: Box<dyn Schedule>, label: impl Into<String>) -> Self {
        let total = sched.total_tokens();
        Self {
            it: problem.iter(),
            sched,
            total,
            tokens: 0,
            step: 0,
            last_phase: 0,
            rows: Vec::new(),
            label: label.into(),
            ckpt_dir: None,
        }
    }

    /// The trajectory so far.
    pub fn rows(&self) -> &[TraceRow] {
        &self.rows
    }
}

impl RunDriver for RecursionDriver {
    fn step(&mut self, _pool: &mut WorkerPool) -> Result<u64> {
        if self.tokens >= self.total {
            return Ok(0);
        }
        // one iteration of the golden drive loop, verbatim
        let p = self.sched.query(self.tokens);
        let cuts = p.phase.saturating_sub(self.last_phase) as u32;
        self.last_phase = p.phase;
        self.it.step(p.lr, p.batch_tokens);
        self.tokens += p.batch_tokens;
        self.step += 1;
        let gnorm = self.it.grad_norm_sq(p.batch_tokens).total();
        let gns = exact_gns(&self.it, p.batch_tokens);
        if let Some(v) = gns {
            self.sched.observe_gns(self.tokens, v);
        }
        self.rows.push(TraceRow {
            step: self.step,
            lr: p.lr,
            batch: p.batch_tokens,
            ce: self.it.risk(),
            gnorm,
            gns,
            cuts,
        });
        Ok(p.batch_tokens)
    }

    fn is_done(&self) -> bool {
        self.tokens >= self.total
    }

    fn finish(&mut self) -> Result<()> {
        let Some(dir) = &self.ckpt_dir else { return Ok(()) };
        // a minimal, deterministic end-of-run checkpoint: enough to prove
        // (in the namespace-isolation tests) that tenant A's file is
        // tenant A's — the final risk bits differ whenever the runs do.
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint namespace {}", dir.display()))?;
        let final_ce = self.rows.last().map(|r| r.ce.to_bits()).unwrap_or(0);
        let body = format!(
            "seesaw-serve recursion checkpoint v1\nlabel: {}\nsteps: {}\ntokens: {}\nfinal_ce_bits: {:016x}\n",
            self.label, self.step, self.tokens, final_ce
        );
        let path = dir.join("latest.ckpt");
        std::fs::write(&path, body)
            .with_context(|| format!("writing {}", path.display()))
    }

    fn traj_identity(&self) -> String {
        format!("recursion:{}", self.label)
    }

    fn exec_fingerprint(&self) -> String {
        // pure single-threaded arithmetic: no topology to fingerprint
        "recursion:inline".to_string()
    }

    fn bind_checkpoint_dir(&mut self, dir: &Path) {
        self.ckpt_dir = Some(dir.to_path_buf());
    }

    fn trace_lines(&self) -> Vec<String> {
        self.rows.iter().map(TraceRow::render).collect()
    }
}
