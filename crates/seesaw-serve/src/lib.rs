//! # seesaw-serve — the multi-tenant run service (DESIGN.md §15)
//!
//! A long-lived coordinator that multiplexes many concurrent training
//! runs over **one** shared [`WorkerPool`]: the unit of traffic is a
//! *run*, not a process. Each tenant submits a [`RunDriver`] into the
//! registry; the service advances runs one step at a time under
//! **deterministic fair-share scheduling** and lends the pool to
//! whichever run is stepping ([`StepEngine::swap_pool`] — threads stay
//! parked across tenant switches instead of being respawned per run).
//!
//! ## Fair share, deterministically
//!
//! Seesaw runs have wildly time-varying per-step footprints: a mid-ramp
//! run at 8× its base batch consumes 8× the tokens (and compute) per
//! step that a fresh run does. Round-robin over *steps* would let it
//! starve its siblings. The scheduler therefore keeps a **virtual time**
//! per run — the tokens it has consumed so far, plus a join offset — and
//! always steps the active run with the minimum `(vtime, id)`. Each step
//! charges the batch tokens it actually consumed, so an 8×-batch run is
//! picked ⅛ as often and every tenant advances at the same *token*
//! rate. The rule reads nothing but the registry and the runs' own
//! returned charges — no clocks, no thread timing — so a given sequence
//! of `submit`/`cancel`/`step` calls always produces the same
//! interleaving, and (because every run owns its full state and the
//! pool is execution-transparent) **any** interleaving leaves each
//! run's trajectory bit-identical to its solo execution; the property
//! test in `tests/serve.rs` pins exactly that.
//!
//! ## Isolation
//!
//! * **Checkpoints**: with a checkpoint root configured, each tenant
//!   gets its own namespace `<root>/<tenant>/` (bound into the driver
//!   before its first step, so resumes read the tenant's own
//!   `latest.ckpt` and never a sibling's).
//! * **Panics**: a step that panics (or errors) evicts *that run* —
//!   state [`RunPhase::Failed`], driver dropped — while the pool and
//!   every sibling run survive untouched. This reuses the engine's
//!   existing `catch_unwind` contract: pool threads already absorb
//!   `GradSource` panics thread-side, and the drivers guarantee the
//!   lent pool is swapped back even when the step's own arithmetic
//!   unwinds.

#![forbid(unsafe_code)]
// House style (matches the workspace): builder-free config structs are
// assembled field by field.
#![allow(clippy::field_reassign_with_default)]

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;

use anyhow::{bail, ensure, Result};
use seesaw_engine::coordinator::WorkerPool;

mod driver;

pub use driver::{RecursionDriver, RunDriver, TraceRow, TrainerDriver};

/// Registry handle of one submitted run (stable for the service's
/// lifetime; indexes the submit order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RunId(pub u64);

impl std::fmt::Display for RunId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "run#{}", self.0)
    }
}

/// Lifecycle phase of a registered run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunPhase {
    /// In the scheduler's rotation.
    Active,
    /// Budget spent, `finish()` ran; results remain readable.
    Done,
    /// Evicted by [`Serve::cancel`]; driver dropped, no finalize.
    Cancelled,
    /// Evicted by a step error or panic; driver dropped.
    Failed,
}

/// One registry entry: tenant → run, with the identity pair recorded at
/// submit (so `list`/`poll` answer "what is this run" without touching
/// the driver).
struct RunHandle {
    id: RunId,
    tenant: String,
    traj_identity: String,
    exec_fingerprint: String,
    /// Fair-share virtual time: tokens consumed + join offset.
    vtime: u128,
    steps: u64,
    tokens: u64,
    state: RunState,
}

enum RunState {
    Active(Box<dyn RunDriver>),
    /// Kept (not dropped) so results stay readable via [`Serve::trace`].
    Done(Box<dyn RunDriver>),
    Cancelled,
    Failed(String),
}

impl RunState {
    fn phase(&self) -> RunPhase {
        match self {
            RunState::Active(_) => RunPhase::Active,
            RunState::Done(_) => RunPhase::Done,
            RunState::Cancelled => RunPhase::Cancelled,
            RunState::Failed(_) => RunPhase::Failed,
        }
    }
}

/// Poll/list snapshot of one run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunStatus {
    pub id: RunId,
    pub tenant: String,
    pub phase: RunPhase,
    /// Eviction reason when `phase == Failed`.
    pub error: Option<String>,
    /// Scheduler steps executed.
    pub steps: u64,
    /// Tokens consumed (== the run's fair-share charge so far).
    pub tokens: u64,
    pub traj_identity: String,
    pub exec_fingerprint: String,
}

/// The multi-tenant run service: registry + fair-share scheduler + the
/// one shared worker pool.
pub struct Serve {
    pool: WorkerPool,
    checkpoint_root: Option<PathBuf>,
    runs: Vec<RunHandle>,
}

impl Default for Serve {
    fn default() -> Self {
        Self::new(None)
    }
}

impl Serve {
    /// A service with an optional checkpoint root; each tenant
    /// checkpoints under `<root>/<tenant>/`.
    pub fn new(checkpoint_root: Option<PathBuf>) -> Self {
        Self { pool: WorkerPool::default(), checkpoint_root, runs: Vec::new() }
    }

    /// The tenant's checkpoint namespace under the service root (`None`
    /// when the service was built without one). The CLI uses this to
    /// point a `Trainer`'s config at the right directory before
    /// wrapping it in a [`TrainerDriver`].
    pub fn checkpoint_namespace(&self, tenant: &str) -> Option<PathBuf> {
        self.checkpoint_root.as_ref().map(|r| r.join(tenant))
    }

    /// Register a run for `tenant` and enter it into the scheduler
    /// rotation. The tenant name becomes a directory component, so it
    /// is validated; one *active* run per tenant (resubmitting after
    /// the previous run reached a terminal phase is fine). The new run
    /// joins at the minimum active virtual time — it gets its fair
    /// share from now on, but no retroactive credit for steps it was
    /// not registered for.
    pub fn submit(&mut self, tenant: &str, mut driver: Box<dyn RunDriver>) -> Result<RunId> {
        validate_tenant(tenant)?;
        if self
            .runs
            .iter()
            .any(|r| r.tenant == tenant && matches!(r.state, RunState::Active(_)))
        {
            bail!("tenant {tenant:?} already has an active run");
        }
        if let Some(ns) = self.checkpoint_namespace(tenant) {
            driver.bind_checkpoint_dir(&ns);
        }
        let id = RunId(self.runs.len() as u64);
        let join_vtime =
            self.runs
                .iter()
                .filter(|r| matches!(r.state, RunState::Active(_)))
                .map(|r| r.vtime)
                .min()
                .unwrap_or(0);
        self.runs.push(RunHandle {
            id,
            tenant: tenant.to_string(),
            traj_identity: driver.traj_identity(),
            exec_fingerprint: driver.exec_fingerprint(),
            vtime: join_vtime,
            steps: 0,
            tokens: 0,
            state: RunState::Active(driver),
        });
        Ok(id)
    }

    /// Snapshot one run (`None`: unknown id).
    pub fn poll(&self, id: RunId) -> Option<RunStatus> {
        self.runs.get(id.0 as usize).map(status_of)
    }

    /// Snapshot every registered run, in submit order.
    pub fn list(&self) -> Vec<RunStatus> {
        self.runs.iter().map(status_of).collect()
    }

    /// Evict an active run: driver dropped (its end-of-run effects never
    /// run), phase [`RunPhase::Cancelled`]; the pool and every sibling
    /// are untouched. Errors on an unknown id or a run already out of
    /// the rotation.
    pub fn cancel(&mut self, id: RunId) -> Result<()> {
        let Some(run) = self.runs.get_mut(id.0 as usize) else {
            bail!("unknown run {id}");
        };
        ensure!(
            matches!(run.state, RunState::Active(_)),
            "{id} ({}) is not active (phase {:?})",
            run.tenant,
            run.state.phase()
        );
        run.state = RunState::Cancelled;
        Ok(())
    }

    /// One fair-share scheduling decision: step the active run with the
    /// minimum `(vtime, id)`. Returns the run stepped, or `None` when no
    /// run is active. A step error or panic evicts that run (phase
    /// [`RunPhase::Failed`]) and still returns its id — the service
    /// itself never fails on tenant faults.
    pub fn step(&mut self) -> Option<RunId> {
        let idx = self
            .runs
            .iter()
            .enumerate()
            .filter(|(_, r)| matches!(r.state, RunState::Active(_)))
            .min_by_key(|(_, r)| (r.vtime, r.id))
            .map(|(i, _)| i)?;
        let id = self.runs[idx].id;
        self.step_index(idx);
        Some(id)
    }

    /// Step one specific run, bypassing the fair-share pick (the
    /// interleaving-invariance property test drives this directly).
    /// Returns `false` when the run is not in the rotation; errors on an
    /// unknown id.
    pub fn step_run(&mut self, id: RunId) -> Result<bool> {
        let Some(run) = self.runs.get(id.0 as usize) else {
            bail!("unknown run {id}");
        };
        if !matches!(run.state, RunState::Active(_)) {
            return Ok(false);
        }
        self.step_index(id.0 as usize);
        Ok(true)
    }

    /// Run the scheduler until every registered run has left the
    /// rotation; returns the number of steps executed.
    pub fn drain(&mut self) -> u64 {
        let mut steps = 0u64;
        while self.step().is_some() {
            steps += 1;
        }
        steps
    }

    /// The trajectory of a run that still holds its driver (active or
    /// done), as golden-comparable data lines.
    pub fn trace(&self, id: RunId) -> Option<Vec<String>> {
        match &self.runs.get(id.0 as usize)?.state {
            RunState::Active(d) | RunState::Done(d) => Some(d.trace_lines()),
            _ => None,
        }
    }

    /// The one-line human summary of a run that still holds its driver
    /// (the CLI's end-of-run report line).
    pub fn summary(&self, id: RunId) -> Option<String> {
        match &self.runs.get(id.0 as usize)?.state {
            RunState::Active(d) | RunState::Done(d) => d.summary(),
            _ => None,
        }
    }

    /// Live threads in the shared pool (diagnostics; they persist parked
    /// across runs and tenant switches).
    pub fn pool_threads(&self) -> usize {
        self.pool.live_threads()
    }

    /// Advance the run at registry index `idx` by one step, charging its
    /// virtual time and handling completion/eviction. The pool and the
    /// run entry are disjoint borrows of `self`, so the driver can hold
    /// the pool while the entry is updated around it.
    fn step_index(&mut self, idx: usize) {
        let pool = &mut self.pool;
        let run = &mut self.runs[idx];
        let RunState::Active(driver) = &mut run.state else { return };
        // Defense in depth: drivers catch their own mid-step panics (and
        // always swap the lent pool back), but a panic escaping a
        // misbehaving driver must still only evict that run.
        let stepped = catch_unwind(AssertUnwindSafe(|| driver.step(pool)));
        match stepped {
            Ok(Ok(charge)) => {
                run.steps += 1;
                run.tokens += charge;
                run.vtime += charge as u128;
                if driver.is_done() {
                    let finished = driver.finish();
                    let taken = std::mem::replace(&mut run.state, RunState::Cancelled);
                    let RunState::Active(d) = taken else { unreachable!("matched Active above") };
                    run.state = match finished {
                        Ok(()) => RunState::Done(d),
                        Err(e) => RunState::Failed(format!("finalize failed: {e:#}")),
                    };
                }
            }
            Ok(Err(e)) => {
                run.state = RunState::Failed(format!("step failed: {e:#}"));
            }
            Err(payload) => {
                run.state =
                    RunState::Failed(format!("step panicked: {}", driver::panic_msg(&*payload)));
            }
        }
    }
}

fn status_of(r: &RunHandle) -> RunStatus {
    RunStatus {
        id: r.id,
        tenant: r.tenant.clone(),
        phase: r.state.phase(),
        error: match &r.state {
            RunState::Failed(e) => Some(e.clone()),
            _ => None,
        },
        steps: r.steps,
        tokens: r.tokens,
        traj_identity: r.traj_identity.clone(),
        exec_fingerprint: r.exec_fingerprint.clone(),
    }
}

/// Tenant names become checkpoint directory components: restrict to a
/// conservative charset and refuse path tricks.
fn validate_tenant(tenant: &str) -> Result<()> {
    ensure!(!tenant.is_empty(), "tenant name must not be empty");
    ensure!(tenant.len() <= 64, "tenant name over 64 bytes: {tenant:?}");
    ensure!(
        tenant.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.')),
        "tenant name may only contain [A-Za-z0-9._-]: {tenant:?}"
    );
    ensure!(tenant != "." && tenant != "..", "tenant name must not be a dot path: {tenant:?}");
    Ok(())
}
